"""Unified Backend API: the same WorkflowSpec deploys through the one
``core.workflow.deploy`` path on SimCloud *and* the concurrent LocalRunner,
and produces the same execution sets and results — semantic parity, not
timing parity (the Backend-Shim portability claim, paper §3.2 / Table 2).
"""

import math
from collections import Counter

import pytest

from repro.backends import shim
from repro.backends.localjax import LocalRunner, deploy_local
from repro.backends.simcloud import SimCloud, Workload
from repro.core import workflow as wf
from repro.core.subgraph import WorkflowSpec

AWS = "aws/lambda"
ALI = "aliyun/fc"


# ---- workflow zoo (one builder per invocation-primitive family) -------------


def seq_spec():
    spec = WorkflowSpec("p-seq", gc=True)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x + 1))
    spec.function("b", ALI, workload=Workload(fn=lambda x: x * 2))
    spec.sequence("a", "b")
    return spec, 3, "b", 8


def diamond_spec():
    spec = WorkflowSpec("p-diamond", gc=False)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    for i, f in enumerate(["b", "c", "d"]):
        spec.function(f, ALI if i % 2 else AWS,
                      workload=Workload(fn=lambda x, i=i: x + i))
    spec.function("agg", ALI, workload=Workload(fn=lambda xs: sorted(xs)))
    spec.fanout("a", ["b", "c", "d"])
    spec.fanin(["b", "c", "d"], "agg")
    return spec, 10, "agg", [10, 11, 12]


def map_spec():
    spec = WorkflowSpec("p-map", gc=False)
    spec.function("split", AWS, workload=Workload(fn=lambda n: list(range(n))))
    spec.function("work", ALI, workload=Workload(fn=lambda x: x * x))
    spec.function("agg", AWS, workload=Workload(fn=sum))
    spec.map("split", "work")
    spec.fanin(["work"], "agg")
    return spec, 6, "agg", sum(i * i for i in range(6))


def loop_spec():
    spec = WorkflowSpec("p-loop", gc=False)
    spec.function("inc", AWS, workload=Workload(fn=lambda x: x + 1))
    spec.function("even", ALI, workload=Workload(fn=lambda x: ("even", x)))
    spec.function("odd", ALI, workload=Workload(fn=lambda x: ("odd", x)))
    spec.cycle("inc", "inc", while_pred=lambda x: x < 5)
    spec.choice("inc", [(lambda x: x % 2 == 0, "even"), (None, "odd")])
    return spec, 0, "odd", ("odd", 5)


def redundant_spec():
    spec = WorkflowSpec("p-red", gc=False)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    spec.function("b", ALI, workload=Workload(fn=lambda x: x * 10))
    spec.function("c", AWS, workload=Workload(fn=lambda x: x))
    spec.redundant("a", "b", replicas=[ALI, AWS])
    spec.sequence("b", "c")
    return spec, 4, "c", 40


CASES = {
    "sequence": seq_spec,
    "diamond": diamond_spec,
    "map": map_spec,
    "cycle_choice": loop_spec,
    "redundant": redundant_spec,
}


def _run_on(kind: str, build, **deploy_kw):
    spec, input_value, terminal, expected = build()
    backend = SimCloud(seed=0) if kind == "sim" else LocalRunner()
    dep = wf.deploy(backend, spec, **deploy_kw)
    wid = dep.start(input_value)
    if kind == "sim":
        backend.run()
    else:
        backend.run(timeout_s=60.0)
    done = Counter(r.function for r in dep.executions(wid)
                   if r.status == "done")
    return {
        "backend": backend,
        "dep": dep,
        "wid": wid,
        "done": done,
        "result": dep.result_of(wid, terminal),
        "expected": expected,
        "makespan": dep.makespan_ms(wid),
    }


# ---- the parity suite ------------------------------------------------------


@pytest.mark.parametrize("case", sorted(CASES))
def test_same_spec_same_semantics_on_both_backends(case):
    sim = _run_on("sim", CASES[case])
    loc = _run_on("local", CASES[case])
    # identical execution sets (which functions completed, how many times)
    assert sim["done"] == loc["done"], (sim["done"], loc["done"])
    # identical terminal values through result_of
    assert sim["result"] == sim["expected"]
    assert loc["result"] == loc["expected"]
    # finite makespans on both substrates (virtual vs wall — only finiteness
    # and positivity are comparable)
    assert math.isfinite(sim["makespan"]) and sim["makespan"] > 0
    assert math.isfinite(loc["makespan"]) and loc["makespan"] > 0
    # zero drops on a healthy run, both sides
    assert not sim["backend"].dropped
    assert not loc["backend"].dropped


def test_both_backends_satisfy_the_protocol():
    assert isinstance(SimCloud(), shim.Backend)
    assert isinstance(LocalRunner(), shim.Backend)


def test_catalogs_agree_on_substrate_shape():
    """Both backends derive their Catalog from the same config, including
    the cheapest-flavor GC-host rule."""
    sim_cat = SimCloud().catalog()
    loc_cat = LocalRunner().catalog()
    assert sim_cat.tables == loc_cat.tables
    assert sim_cat.objects == loc_cat.objects
    assert sim_cat.quotas == loc_cat.quotas
    assert sim_cat.gc_faas == loc_cat.gc_faas


def test_deploy_local_is_a_thin_alias_of_unified_deploy():
    """deploy_local must route through core.workflow.deploy and return a
    fully-functional DeployedWorkflow (executions / makespan_ms /
    result_of all work on the LocalRunner deployment)."""
    spec, input_value, terminal, expected = seq_spec()
    runner = LocalRunner()
    dep = deploy_local(runner, spec)
    assert isinstance(dep, wf.DeployedWorkflow)
    assert dep.backend is runner
    wid = dep.start(input_value)
    runner.run(timeout_s=60.0)
    assert dep.result_of(wid, terminal) == expected
    assert math.isfinite(dep.makespan_ms(wid))
    assert {r.function for r in dep.executions(wid)
            if r.status == "done"} == {"a", "b"}


def test_record_query_surface_parity():
    """executions_of / completed serve the same views on both backends."""
    for kind in ("sim", "local"):
        out = _run_on(kind, map_spec)
        backend = out["backend"]
        works = backend.executions_of("work")
        assert len([r for r in works if r.status == "done"]) == 6
        completed = backend.completed()
        assert [r.exec_id for r in completed] == sorted(
            r.exec_id for r in completed)
        assert {r.function for r in completed} >= {"split", "work", "agg"}


def test_replan_degrades_gracefully_without_topology():
    """A backend without a network model must yield a clear CapabilityError
    from replan(), never an AttributeError (the capability-probe rule)."""
    spec, input_value, terminal, _ = seq_spec()
    runner = LocalRunner()
    dep = wf.deploy(runner, spec)
    wid = dep.start(input_value)
    runner.run(timeout_s=60.0)
    with pytest.raises(shim.CapabilityError, match="topology"):
        dep.replan(excluded_clouds=["aliyun"])
    # ... and the deployment keeps serving results after the refused replan
    assert dep.result_of(wid, terminal) is not None


def test_submit_delay_contract_on_both_backends():
    """submit(t=) is a *delay* on every backend (virtual ms on SimCloud,
    wall ms on LocalRunner): honored relative to the backend's clock, and
    negative values rejected loudly — never clamped or ignored."""
    spec, input_value, terminal, expected = seq_spec()
    sim = SimCloud(seed=0)
    dep = wf.deploy(sim, spec)
    w0 = dep.start(input_value)
    sim.run()
    t_mid = sim.now
    w1 = dep.start(input_value, t=250.0)          # delay from now, not t=250 absolute
    sim.run()
    assert dep.result_of(w1, terminal) == expected
    first = min(r.t_queued for r in dep.executions(w1))
    assert first >= t_mid + 250.0
    with pytest.raises(ValueError):
        sim.submit(AWS, "a", {"workflow_id": "neg", "input": 0}, t=-1.0)


def test_learn_profiles_works_on_local_records():
    """The trace-calibration loop is backend-agnostic: wall-clock local
    records feed EdgeProfiles just like virtual-clock SimCloud ones."""
    out = _run_on("local", seq_spec)
    profiles = out["dep"].learn_profiles()
    assert profiles.nodes["a"].samples >= 1
    assert profiles.nodes["b"].out_bytes > 0


# ---- durable execution: journal round-trip parity --------------------------
#
# deploy(durable=True) + kill + fresh-backend resume() must behave the same
# on both substrates: the journal is plain datastore state, so recovery is
# substrate-blind.  (SimCloud dies via an unrecoverable outage; LocalRunner
# via a crash policy that exhausts the retry budget.  The real-SIGKILL
# variant is the `benchmarks/durability_smoke.py` CI gate.)


def durable_seq_spec(calls):
    spec = WorkflowSpec("p-dur", gc=False)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x + 1))
    spec.function("b", ALI,
                  workload=Workload(fn=lambda x: calls.append(x) or x * 2))
    spec.sequence("a", "b")
    return spec


def _interrupted_durable_run(kind, calls):
    """Start a durable run and kill it mid-flight; return (backend, wid)."""
    if kind == "sim":
        backend = SimCloud(seed=0)
        dep = wf.deploy(backend, durable_seq_spec(calls), durable=True)
        backend.schedule_outage("aliyun", 5.0, float("inf"))
        wid = dep.start(3)
        backend.run()
    else:
        backend = LocalRunner(concurrency=2, max_requeues=1,
                              retry_backoff_ms=5.0)
        dep = wf.deploy(backend, durable_seq_spec(calls), durable=True)
        backend.crash_policy = (lambda ex, eff:
                                ex.record.function == "b"
                                and ex.effect_index >= 4)
        wid = dep.start(3, workflow_id="p-dur-000000")
        backend.run(timeout_s=30.0)
        backend.crash_policy = None
    assert backend.dropped, "the interruption must exhaust the retry budget"
    assert dep.result_of(wid, "b") is None
    return backend, wid


def _fresh_over_same_stores(kind, old):
    backend = SimCloud(seed=1) if kind == "sim" else LocalRunner(concurrency=2)
    backend.adopt_stores(old)
    return backend


@pytest.mark.parametrize("kind", ["sim", "local"])
def test_journal_round_trip_resumes_identically(kind):
    """Interrupt → fresh backend over the same stores → resume(): the same
    recovery idiom completes the workflow on either substrate, exactly-once."""
    calls = []
    old, wid = _interrupted_durable_run(kind, calls)
    fresh = _fresh_over_same_stores(kind, old)
    dep = wf.deploy(fresh, durable_seq_spec(calls), durable=True)
    fids = dep.resume()
    assert fids and all(f.startswith(wid + "/") for f in fids), fids
    if kind == "sim":
        fresh.run()
    else:
        fresh.run(timeout_s=30.0)
        fresh.close()
    assert dep.result_of(wid, "b") == 8
    assert calls == [4], "user function ran exactly once across both lives"
    # second-generation resume: the journal is closed, nothing left
    third = _fresh_over_same_stores(kind, fresh)
    dep3 = wf.deploy(third, durable_seq_spec(calls), durable=True)
    assert dep3.resume() == []


@pytest.mark.parametrize("kind", ["sim", "local"])
def test_completed_durable_run_has_nothing_to_resume(kind):
    """A durable run that finishes cleanly leaves a closed journal: resume()
    on a fresh backend over the same stores is a no-op on both substrates."""
    calls = []
    if kind == "sim":
        backend = SimCloud(seed=0)
        dep = wf.deploy(backend, durable_seq_spec(calls), durable=True)
        wid = dep.start(3)
        backend.run()
    else:
        backend = LocalRunner(concurrency=2)
        dep = wf.deploy(backend, durable_seq_spec(calls), durable=True)
        wid = dep.start(3)
        backend.run(timeout_s=30.0)
    assert dep.result_of(wid, "b") == 8
    assert calls == [4]
    fresh = _fresh_over_same_stores(kind, backend)
    dep2 = wf.deploy(fresh, durable_seq_spec(calls), durable=True)
    assert dep2.resume() == []


@pytest.mark.parametrize("case", sorted(CASES))
def test_durable_mode_preserves_parity_semantics(case):
    """The whole workflow zoo still satisfies the parity contract with
    journaling on: same done-sets, same results, zero drops — the journal
    must be an invisible layer on a healthy run."""
    spec, input_value, terminal, expected = CASES[case]()
    for kind in ("sim", "local"):
        backend = SimCloud(seed=0) if kind == "sim" else LocalRunner()
        dep = wf.deploy(backend, spec, durable=True)
        wid = dep.start(input_value)
        if kind == "sim":
            backend.run()
        else:
            backend.run(timeout_s=60.0)
        assert dep.result_of(wid, terminal) == expected, kind
        assert not backend.dropped, kind


# ---- speculative pre-fetching: the third capability-gated parity axis -------


@pytest.mark.parametrize("case", sorted(CASES))
def test_prefetch_mode_preserves_parity_semantics(case):
    """The whole workflow zoo with speculative pre-fetching on: same
    results, zero drops on both substrates — prefetch must be a pure
    latency optimization, invisible to workflow semantics."""
    spec, input_value, terminal, expected = CASES[case]()
    for kind in ("sim", "local"):
        backend = SimCloud(seed=0) if kind == "sim" else LocalRunner()
        dep = wf.deploy(backend, spec, prefetch=True)
        wid = dep.start(input_value)
        if kind == "sim":
            backend.run()
        else:
            backend.run(timeout_s=60.0)
        assert dep.result_of(wid, terminal) == expected, kind
        assert not backend.dropped, kind


def prefetch_fanin_spec():
    """A shape where directives actually arm: big predictable fan-in reads
    with the datastore in the producers' cloud and the aggregator across."""
    spec = WorkflowSpec("p-pf", gc=False)
    spec.function("s", AWS,
                  workload=Workload(out_bytes=64, fn=lambda x: x))
    for p in ("p1", "p2", "p3"):
        spec.function(p, AWS, workload=Workload(
            out_bytes=3_500_000,
            fn=lambda x: shim.Blob(3_500_000, "t")))
    spec.function("agg", ALI, workload=Workload(
        out_bytes=8, fn=lambda xs: len(xs)))
    spec.fanout("s", ["p1", "p2", "p3"])
    spec.fanin(["p1", "p2", "p3"], "agg")
    return spec, 1, "agg", 3


def test_prefetch_armed_parity_on_fanin():
    """With directives genuinely armed (not just the capability on), both
    backends still produce identical execution sets and results."""
    sim = _run_on("sim", prefetch_fanin_spec, prefetch=True)
    loc = _run_on("local", prefetch_fanin_spec, prefetch=True)
    assert sim["done"] == loc["done"], (sim["done"], loc["done"])
    assert sim["result"] == sim["expected"]
    assert loc["result"] == loc["expected"]
    assert not sim["backend"].dropped and not loc["backend"].dropped


def test_prefetch_capability_probe_is_uniform():
    """Both substrates expose the capability attribute; a disabled local
    runner degrades to CapabilityError at deploy time, not mid-run."""
    assert SimCloud().prefetch and LocalRunner().prefetch
    spec, _, _, _ = prefetch_fanin_spec()
    with pytest.raises(shim.CapabilityError, match="prefetch"):
        wf.deploy(LocalRunner(prefetch=False), spec, prefetch=True)


def test_legacy_sim_alias_still_points_at_backend():
    """`DeployedWorkflow.sim` predates the Backend protocol; it must remain
    a pure alias of `.backend` on every substrate (guard for the sweep that
    moved all call sites onto `.backend`)."""
    for backend in (SimCloud(seed=0), LocalRunner()):
        spec, _, _, _ = seq_spec()
        dep = wf.deploy(backend, spec)
        assert dep.sim is dep.backend is backend
