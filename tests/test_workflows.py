"""Workflow-level integration on SimCloud: every primitive, placement, GC."""

import pytest

from repro.backends import shim
from repro.backends.simcloud import Blob, SimCloud, Workload
from repro.core import workflow as wf
from repro.core.placement import best_placement, choose_flavor, majority_cloud
from repro.core.subgraph import WorkflowSpec, compile_workflow

AWS = "aws/lambda"
ALI = "aliyun/fc"


def _run(spec, input_value=0, seed=0):
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec)
    wid = dep.start(input_value)
    sim.run()
    return sim, dep, wid


def test_sequence_cross_cloud():
    spec = WorkflowSpec("seq")
    spec.function("a", AWS, workload=Workload(fixed_ms=5, fn=lambda x: x + 1))
    spec.function("b", ALI, workload=Workload(fixed_ms=5, fn=lambda x: x * 2))
    spec.sequence("a", "b")
    sim, dep, wid = _run(spec, 3)
    assert dep.result_of(wid, "b") == 8


def test_static_fanout_fanin():
    spec = WorkflowSpec("diamond")
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    for i, f in enumerate(["b", "c", "d"]):
        spec.function(f, ALI if i % 2 else AWS,
                      workload=Workload(fn=lambda x, i=i: x + i))
    spec.function("agg", ALI, workload=Workload(fn=lambda xs: sorted(xs)))
    spec.fanout("a", ["b", "c", "d"])
    spec.fanin(["b", "c", "d"], "agg")
    sim, dep, wid = _run(spec, 10)
    assert dep.result_of(wid, "agg") == [10, 11, 12]
    # exactly one aggregator execution (the bitmap-complete peer invokes it)
    aggs = [r for r in dep.executions(wid) if r.function == "agg"
            and r.status == "done"]
    assert len(aggs) == 1


def test_dynamic_map_fanin():
    spec = WorkflowSpec("map")
    spec.function("split", AWS, workload=Workload(fn=lambda n: list(range(n))))
    spec.function("work", ALI, workload=Workload(fn=lambda x: x * x))
    spec.function("agg", AWS, workload=Workload(fn=sum))
    spec.map("split", "work")
    spec.fanin(["work"], "agg")
    sim, dep, wid = _run(spec, 6)
    assert dep.result_of(wid, "agg") == sum(i * i for i in range(6))


def test_choice_and_cycle():
    spec = WorkflowSpec("loop")
    spec.function("inc", AWS, workload=Workload(fn=lambda x: x + 1))
    spec.function("even", ALI, workload=Workload(fn=lambda x: ("even", x)))
    spec.function("odd", ALI, workload=Workload(fn=lambda x: ("odd", x)))
    spec.cycle("inc", "inc", while_pred=lambda x: x < 5)
    spec.choice("inc", [(lambda x: x % 2 == 0, "even"), (None, "odd")])
    sim, dep, wid = _run(spec, 0)
    assert dep.result_of(wid, "odd") == ("odd", 5)
    assert dep.result_of(wid, "even") is None


def test_large_fanout_grouped_checkpoints():
    """>10 successors exercises the chunk-of-10 invocation checkpointing."""
    n = 25
    spec = WorkflowSpec("wide", gc=False)
    spec.function("src", AWS, workload=Workload(fn=lambda x: list(range(n))))
    spec.function("w", ALI, workload=Workload(fn=lambda x: x + 1))
    spec.function("agg", AWS, workload=Workload(fn=sum))
    spec.map("src", "w")
    spec.fanin(["w"], "agg")
    sim, dep, wid = _run(spec, 0)
    assert dep.result_of(wid, "agg") == sum(range(1, n + 1))


def test_indirect_transfer_over_quota():
    """Payloads above the FaaS async quota go through the datastore."""
    big = Blob(5_000_000, "big")
    spec = WorkflowSpec("big")
    spec.function("a", AWS, workload=Workload(fn=lambda x: big))
    spec.function("b", ALI, workload=Workload(fn=lambda x: x.nbytes))
    spec.sequence("a", "b")
    sim, dep, wid = _run(spec)
    assert dep.result_of(wid, "b") == 5_000_000


def test_gc_sweeps_workflow_prefix():
    spec = WorkflowSpec("gc-test", gc=True)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    spec.function("b", ALI, workload=Workload(fn=lambda x: x))
    spec.sequence("a", "b")
    sim, dep, wid = _run(spec)
    leftovers = [k for st in sim.stores.values()
                 for k in st.state.items if k.startswith(wid)]
    assert leftovers == []


def test_failover_to_backup():
    spec = WorkflowSpec("fo")
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    spec.function("b", ALI, failover=[AWS], workload=Workload(fn=lambda x: x + 1))
    spec.sequence("a", "b")
    sim = SimCloud(seed=1)
    dep = wf.deploy(sim, spec)
    sim.schedule_outage("aliyun", 0, 1e9)
    wid = dep.start(1)
    sim.run()
    done = [r for r in dep.executions(wid) if r.function == "b"
            and r.status == "done"]
    assert done and done[0].faas == AWS
    assert dep.result_of(wid, "b") == 2


def test_redundant_first_wins():
    spec = WorkflowSpec("red", gc=False)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    spec.function("b", ALI, workload=Workload(fixed_ms=30, fn=lambda x: x * 10))
    spec.function("c", AWS, workload=Workload(fn=lambda x: x))
    spec.redundant("a", "b", replicas=[ALI, AWS])
    spec.sequence("b", "c")
    sim, dep, wid = _run(spec, 4)
    assert dep.result_of(wid, "c") == 40
    # both replicas may run, but downstream executed exactly once
    cs = [r for r in dep.executions(wid) if r.function == "c"
          and r.status == "done"]
    assert len(cs) == 1


def test_bybatch_accumulates_across_workflows():
    spec = WorkflowSpec("batcher", gc=False)
    spec.function("produce", AWS, workload=Workload(fn=lambda x: x))
    spec.function("consume", ALI, workload=Workload(fn=lambda xs: sorted(xs)))
    spec.batch("produce", "consume", batch_size=3)
    sim = SimCloud(seed=2)
    dep = wf.deploy(sim, spec)
    wids = [dep.start(i, t=i * 500.0) for i in range(7)]
    sim.run()
    consumed = [r.result for r in sim.records
                if r.function == "consume" and r.status == "done"]
    # 7 producers, batch=3 ⇒ exactly 2 consumer firings of 3 items each
    assert len(consumed) == 2
    assert all(len(c) == 3 for c in consumed)


def test_no_global_graph_at_runtime():
    """The NodeView must not reference other nodes' NodeViews (paper: the
    function-side orchestrator sees only its local sub-graph)."""
    spec = WorkflowSpec("iso")
    spec.function("a", AWS, workload=Workload(fn=lambda x: x))
    spec.function("b", ALI, workload=Workload(fn=lambda x: x))
    spec.sequence("a", "b")
    sim = SimCloud()
    views = compile_workflow(spec, sim.catalog())
    import repro.core.subgraph as sg
    for v in views.values():
        for info in v.next_funcs:
            assert not isinstance(info, sg.NodeView)
            assert isinstance(info.name, str)


# ---- placement (§4.3.1 / §2.1) ---------------------------------------------


def test_majority_rule():
    assert majority_cloud(["aws", "aliyun", "aliyun"]) == "aliyun"
    assert best_placement(["aws", "aliyun", "aliyun"]) == ("aliyun", 1)
    # deterministic tie-break
    assert majority_cloud(["b", "a"]) == "a"


def test_heterogeneity_placement():
    from repro.backends import calibration as cal
    flavors = {"aws/lambda": cal.CPU_AWS, "aliyun/fc_gpu": cal.GPU_ALIYUN_8G}
    fid, dur, usd = choose_flavor(flavors, compute_ms=1500.0)
    assert fid == "aliyun/fc_gpu" and dur == pytest.approx(100.0)
    # cost objective flips when the accelerator premium outweighs the speedup
    pricey_gpu = cal.Flavor("gpu", price_per_gb_s=5e-4, speed=3.0, gpu=True,
                            memory_gb=8.0)
    fid_cost, _, _ = choose_flavor(
        {"aws/lambda": cal.CPU_AWS, "x/gpu": pricey_gpu},
        compute_ms=100.0, objective="cost")
    assert fid_cost == "aws/lambda"
