"""Property tests: exactly-once execution under adversarial crash schedules.

The paper's §4.1 argument — at-least-once delivery ⊕ at-most-once data
production ⊕ at-most-once invocation ⇒ exactly-once — is explored with
hypothesis over (workflow shape × crash schedule × outage windows).  The
SimCloud crash hook aborts executions *between* effects, covering the
"most extreme scenario" (crash after the async invoke, before its
checkpoint) explicitly.

Deterministic (no-hypothesis) coverage of the same properties lives in
``test_exactly_once.py``; this module skips wholesale when hypothesis is
not installed.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.backends.simcloud import SimCloud, Workload
from repro.core import workflow as wf
from repro.core.subgraph import WorkflowSpec

from test_exactly_once import AWS, ALI, effectful_spec, periodic_crash_policy


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    fanout=st.integers(min_value=1, max_value=5),
    crash_period=st.integers(min_value=3, max_value=60),
    crash_count=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_exactly_once_under_crashes(fanout, crash_period, crash_count, seed):
    spec, calls, expected = effectful_spec(fanout)
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec)

    sim.crash_policy = periodic_crash_policy(crash_period, crash_count)
    wid = dep.start(0)
    sim.run()
    sim.crash_policy = None

    tails = [r for r in dep.executions(wid)
             if r.function == "tail" and r.status == "done"]
    # Completion is guaranteed only while crashes stay within the substrate's
    # at-least-once retry budget (a function crashed MAX_RETRIES+1 times is
    # legitimately dropped — sim.dropped).  Exactly-once must hold regardless.
    if not sim.dropped:
        assert calls["tail"].count(expected) >= 1
    # exactly-once SEMANTICS: every completed tail observed the same value,
    # and the workflow's data (checkpointed outputs) is single-valued
    assert all(r.result == expected for r in tails)
    # at-most-once data production: if agg committed, it committed once
    agg_outputs = [s.state.get(k) for s in sim.stores.values()
                   for k in s.state.items
                   if "agg" in k and k.endswith("-output")]
    assert len(agg_outputs) <= 1
    if tails or agg_outputs:
        assert agg_outputs == [{"v": expected}]


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    outage_start=st.floats(min_value=0.0, max_value=400.0),
    outage_len=st.floats(min_value=10.0, max_value=2000.0),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_exactly_once_under_outage_with_failover(outage_start, outage_len, seed):
    """A whole-cloud outage mid-workflow: failover keeps the run exactly-once."""
    spec = WorkflowSpec("outage", gc=False)
    spec.function("a", AWS, workload=Workload(fixed_ms=20, fn=lambda x: x + 1))
    spec.function("b", ALI, failover=[AWS],
                  workload=Workload(fixed_ms=20, fn=lambda x: x * 2))
    spec.function("c", AWS, workload=Workload(fixed_ms=20, fn=lambda x: x - 3))
    spec.sequence("a", "b")
    spec.sequence("b", "c")
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec)
    sim.schedule_outage("aliyun", outage_start, outage_start + outage_len)
    wid = dep.start(5)
    sim.run()
    cs = [r for r in dep.executions(wid) if r.function == "c"
          and r.status == "done"]
    assert cs, "workflow must complete despite the outage"
    assert all(r.result == (5 + 1) * 2 - 3 for r in cs)
    # at-most-once invocation: downstream of b, c commits one output
    c_outs = [s.state.get(k) for s in sim.stores.values()
              for k in s.state.items if "/c_" in k and k.endswith("-output")]
    assert len(c_outs) == 1

# ==========================================================================
# Durable execution: the crash schedules above, replayed through the journal
# ==========================================================================
#
# With deploy(durable=True), a crash schedule may exhaust the substrate's
# retry budget (sim.dropped) — but the open journal makes the drop
# recoverable: a fresh backend over the same stores replays every
# started-but-unfinished function to its suspension point and continues.
# These properties assert the §4.1 invariants *and* guaranteed completion
# after recovery.

from repro.backends import shim  # noqa: E402


def spare_first_effect(policy):
    """Never crash an attempt before its journal-start marker commits.

    An invocation whose every attempt dies before effect 0 leaves no
    journal, which is unrecoverable by design (there is nothing to replay
    and at-least-once redelivery is the only cure); the completeness
    guarantee under test starts once the journal is open.
    """

    def crash(ex, effect):
        if ex.effect_index == 0:
            return False
        return policy(ex, effect)

    return crash


def journal_window_crash_policy(which: str, budget: int):
    """Crash exactly around a journal-entry commit.

    ``which="pre"``: abort when *offered* the ``#j/e`` DsCreate — the live
    effect already ran but its result was never committed, so replay must
    re-run it and the conditional-create data layer must collapse the
    duplicate.  ``which="post"``: abort on the first effect *after* a
    committed entry — the generator resumed past a durable commit, so
    replay must suppress everything up to it.
    """
    state = {"n": budget, "armed": False}

    def crash(ex, effect):
        if state["n"] <= 0:
            return False
        is_commit = (type(effect) is shim.DsCreate
                     and "#j/e" in effect.key)
        if which == "pre":
            if is_commit:
                state["n"] -= 1
                return True
            return False
        fire = state["armed"] and not is_commit
        state["armed"] = is_commit
        if fire:
            state["n"] -= 1
            return True
        return False

    return crash


def _recover_until_quiescent(sim, spec, seed, crash_policy=None, rounds=8,
                             deploy_kw=None, on_fresh=None):
    """The documented recovery idiom, iterated: fresh backend, adopt stores,
    re-deploy durable, resume, run — until resume() finds nothing open.
    ``crash_policy`` (if any) stays armed, so crashes also land mid-replay;
    ``on_fresh`` observes every new backend incarnation before it runs."""
    dep = None
    for i in range(rounds):
        fresh = SimCloud(seed=seed + i + 1)
        fresh.adopt_stores(sim)
        if on_fresh is not None:
            on_fresh(fresh)
        dep = wf.deploy(fresh, spec, durable=True, **(deploy_kw or {}))
        if not dep.resume():
            return sim, dep
        fresh.crash_policy = crash_policy
        fresh.run()
        fresh.crash_policy = None
        sim = fresh
    raise AssertionError("replay recovery did not converge")


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    fanout=st.integers(min_value=1, max_value=4),
    crash_period=st.integers(min_value=3, max_value=40),
    crash_count=st.integers(min_value=0, max_value=10),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_durable_crash_schedule_recovers_to_completion(
        fanout, crash_period, crash_count, seed):
    """Durable upgrade of the crash-schedule property: even when the retry
    budget is exhausted, replay recovery completes the workflow, and the
    §4.1 data invariants hold across the crash boundary."""
    spec, calls, expected = effectful_spec(fanout)
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec, durable=True)
    sim.crash_policy = spare_first_effect(
        periodic_crash_policy(crash_period, crash_count))
    wid = dep.start(0)
    sim.run()
    sim.crash_policy = None

    sim, _ = _recover_until_quiescent(sim, spec, seed)

    # completion is now unconditional (the non-durable property can only
    # assert it when nothing was dropped)
    assert calls["tail"].count(expected) >= 1
    tails = [r for r in dep.executions(wid)
             if r.function == "tail" and r.status == "done"]
    assert all(r.result == expected for r in tails)
    agg_outputs = [s.state.get(k) for s in sim.stores.values()
                   for k in s.state.items
                   if "agg" in k and k.endswith("-output")]
    assert agg_outputs == [{"v": expected}]


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    which=st.sampled_from(["pre", "post"]),
    budget=st.integers(min_value=1, max_value=6),
    fanout=st.integers(min_value=1, max_value=3),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_durable_crash_around_journal_commit(which, budget, fanout, seed):
    """Target the two adversarial windows of the journal protocol itself:
    crash between a live effect and its commit (replay re-runs it), and
    between a commit and the generator's next effect (replay suppresses
    it).  The same policy stays armed during recovery, so crashes also
    land mid-replay."""
    spec, calls, expected = effectful_spec(fanout)
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec, durable=True)
    policy = journal_window_crash_policy(which, budget)
    sim.crash_policy = policy
    wid = dep.start(0)
    sim.run()
    sim.crash_policy = None

    sim, _ = _recover_until_quiescent(sim, spec, seed, crash_policy=policy)

    assert calls["tail"].count(expected) >= 1
    tails = [r for r in dep.executions(wid)
             if r.function == "tail" and r.status == "done"]
    assert all(r.result == expected for r in tails)
    agg_outputs = [s.state.get(k) for s in sim.stores.values()
                   for k in s.state.items
                   if "agg" in k and k.endswith("-output")]
    assert agg_outputs == [{"v": expected}]


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    crash_period=st.integers(min_value=3, max_value=40),
    crash_count=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
    durable=st.booleans(),
)
def test_exactly_once_with_prefetch_under_crashes(crash_period, crash_count,
                                                  seed, durable):
    """Speculative pushes under an adversarial crash schedule, with and
    without the journal: exactly-once data invariants hold, each producer's
    speculative egress is billed at most once per backend life (ledger
    dedupe across retries; journal replay suppresses committed pushes —
    only a push that crashed *before* its journal commit may legitimately
    re-run on a fresh backend, whose ledger died with the old one), and
    durable runs additionally recover to completion."""
    from test_exactly_once import prefetch_spec

    calls = []
    sim = SimCloud(seed=seed)
    lives = []                  # one push-list per backend incarnation

    def spy(backend):
        pushes = []
        orig = backend.bill.charge_egress
        backend.bill.charge_egress = (
            lambda src, nb, price=None:
            pushes.append(nb) or orig(src, nb, price))
        lives.append(pushes)

    spy(sim)
    dep = wf.deploy(sim, prefetch_spec(calls), durable=durable, prefetch=True)
    policy = periodic_crash_policy(crash_period, crash_count)
    sim.crash_policy = spare_first_effect(policy) if durable else policy
    wid = dep.start(1)
    sim.run()
    sim.crash_policy = None

    if durable:
        sim, dep = _recover_until_quiescent(
            sim, prefetch_spec(calls), seed,
            deploy_kw={"prefetch": True}, on_fresh=spy)
        assert calls.count(3) >= 1
    elif not sim.dropped:
        assert calls.count(3) >= 1
    # at-most-once speculative transfer per producer output within each
    # backend life, however many retries the crash schedule forced
    for pushes in lives:
        assert len([n for n in pushes if n == 3_500_000]) <= 3
    agg_outputs = [s.state.get(k) for s in sim.stores.values()
                   for k in s.state.items
                   if "/agg_" in k and k.endswith("-output")]
    assert len(agg_outputs) <= 1
    if agg_outputs:
        assert agg_outputs == [{"v": 3}]


@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    sleep_ms=st.floats(min_value=100.0, max_value=60_000.0),
    outage_len=st.floats(min_value=10.0, max_value=5_000.0),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_durable_crash_mid_sleep_recovers(sleep_ms, outage_len, seed):
    """Kill the substrate while a durable workflow is parked mid-Sleep (an
    outage window straddling the wake-up, no failover): recovery replays
    the journalled absolute deadline, honors the remaining sleep in the
    fresh backend's clock, and the user function still runs exactly once
    per §4.1 data rules."""
    calls = []
    spec = WorkflowSpec("dslp", gc=False)
    spec.function("a", AWS, workload=Workload(fn=lambda x: x * 2))
    spec.function("b", ALI, sleep_ms=sleep_ms,
                  workload=Workload(fn=lambda x: calls.append(x) or x + 10))
    spec.sequence("a", "b")
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec, durable=True)
    # b suspends shortly after t≈0; make aliyun dark across the wake-up
    sim.schedule_outage("aliyun", sleep_ms * 0.5, sleep_ms + outage_len)
    wid = dep.start(3)
    sim.run()

    sim, _ = _recover_until_quiescent(sim, spec, seed)
    # completion + exactly-once, asserted on the shared data layer (records
    # do not transfer across backend incarnations; store states do)
    assert calls.count(6) >= 1
    b_outs = [s.state.get(k) for s in sim.stores.values()
              for k in s.state.items if "/b_" in k and k.endswith("-output")]
    assert b_outs == [{"v": 16}]


# ==========================================================================
# Remote pool: randomized kill -9 schedules over real worker processes
# ==========================================================================
#
# The remote substrate runs user functions in forked worker processes, so
# these properties exercise §4.1 against *real* process death: the crash
# policy's "kill" verdict SIGKILLs the worker mid-attempt and recovery is
# lease expiry + redelivery, not an in-process retry loop.  Crash policies
# execute inside the workers — any cross-attempt state they need must live
# in the shared broker (``ex.runner.chaos_once``) or in the redelivered
# message itself (``ex.record.attempt``), never in test-process closures.

import os                # noqa: E402
import tempfile          # noqa: E402

from conftest import (FileCalls, close_backend, make_backend,  # noqa: E402
                      two_stage_spec)
from test_exactly_once import _kill_window_policy  # noqa: E402


@settings(max_examples=6, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    window=st.sampled_from(["pre", "post", "suspend"]),
    lease_ms=st.sampled_from([700.0, 1100.0, 1500.0]),
    value=st.integers(min_value=0, max_value=50),
)
def test_remote_random_sigkill_window_exactly_once(window, lease_ms, value):
    """Randomized SIGKILL windows over a durable two-stage workflow: kill a
    worker process before stage b's journal commit, right after it, or while
    b is parked mid-suspension.  Whatever the (window, lease, input) draw,
    the pool must run to completion with the fsync'd side-effect log
    exactly-once and a single done record for b."""
    expected = value * 2 + 10
    with tempfile.TemporaryDirectory() as tmp:
        calls = FileCalls(os.path.join(tmp, "calls.log"))
        backend = make_backend("remote", lease_ms=lease_ms,
                               retry_backoff_ms=25.0)
        try:
            sleep_ms = 300.0 if window == "suspend" else 0.0
            dep = wf.deploy(backend, two_stage_spec(calls, sleep_ms=sleep_ms),
                            durable=True)
            backend.crash_policy = _kill_window_policy(
                window, f"kill-{window}")
            wid = dep.start(value, workflow_id=f"prop-{window}-000000")
            backend.run(timeout_s=90.0)
            assert dep.result_of(wid, "b") == expected
            assert calls.values() == [value * 2], \
                f"side-effect log must be exactly-once across the {window} kill"
            assert not backend.dropped
            b_done = [r for r in backend.executions_of("b")
                      if r.status == "done"]
            assert len(b_done) == 1 and b_done[0].result == expected
        finally:
            close_backend(backend)


@settings(max_examples=8, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    crashes=st.integers(min_value=0, max_value=2),
    value=st.integers(min_value=0, max_value=50),
)
def test_remote_soft_crash_schedule_exactly_once(crashes, value):
    """Randomized soft-crash schedules within the requeue budget: the first
    ``crashes`` attempts of stage b abort before user code (the policy keys
    on ``record.attempt`` — redelivered state, valid across processes), the
    surviving attempt completes, and the side-effect log is exactly-once."""
    expected = value * 2 + 10
    with tempfile.TemporaryDirectory() as tmp:
        calls = FileCalls(os.path.join(tmp, "calls.log"))
        backend = make_backend("remote", max_requeues=3,
                               retry_backoff_ms=10.0)
        try:
            dep = wf.deploy(backend, two_stage_spec(calls))
            n = crashes
            backend.crash_policy = (
                lambda ex, eff: ex.record.function == "b"
                and ex.record.attempt < n)
            wid = dep.start(value, workflow_id="prop-soft-000000")
            backend.run(timeout_s=60.0)
            assert dep.result_of(wid, "b") == expected
            assert calls.values() == [value * 2]
            assert not backend.dropped
            crashed = [r for r in backend.executions_of("b")
                       if r.status == "crashed"]
            assert len(crashed) == crashes
        finally:
            close_backend(backend)
