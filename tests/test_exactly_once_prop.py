"""Property tests: exactly-once execution under adversarial crash schedules.

The paper's §4.1 argument — at-least-once delivery ⊕ at-most-once data
production ⊕ at-most-once invocation ⇒ exactly-once — is explored with
hypothesis over (workflow shape × crash schedule × outage windows).  The
SimCloud crash hook aborts executions *between* effects, covering the
"most extreme scenario" (crash after the async invoke, before its
checkpoint) explicitly.

Deterministic (no-hypothesis) coverage of the same properties lives in
``test_exactly_once.py``; this module skips wholesale when hypothesis is
not installed.
"""

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.backends.simcloud import SimCloud, Workload
from repro.core import workflow as wf
from repro.core.subgraph import WorkflowSpec

from test_exactly_once import AWS, ALI, effectful_spec, periodic_crash_policy


@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    fanout=st.integers(min_value=1, max_value=5),
    crash_period=st.integers(min_value=3, max_value=60),
    crash_count=st.integers(min_value=0, max_value=8),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_exactly_once_under_crashes(fanout, crash_period, crash_count, seed):
    spec, calls, expected = effectful_spec(fanout)
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec)

    sim.crash_policy = periodic_crash_policy(crash_period, crash_count)
    wid = dep.start(0)
    sim.run()
    sim.crash_policy = None

    tails = [r for r in dep.executions(wid)
             if r.function == "tail" and r.status == "done"]
    # Completion is guaranteed only while crashes stay within the substrate's
    # at-least-once retry budget (a function crashed MAX_RETRIES+1 times is
    # legitimately dropped — sim.dropped).  Exactly-once must hold regardless.
    if not sim.dropped:
        assert calls["tail"].count(expected) >= 1
    # exactly-once SEMANTICS: every completed tail observed the same value,
    # and the workflow's data (checkpointed outputs) is single-valued
    assert all(r.result == expected for r in tails)
    # at-most-once data production: if agg committed, it committed once
    agg_outputs = [s.state.get(k) for s in sim.stores.values()
                   for k in s.state.items
                   if "agg" in k and k.endswith("-output")]
    assert len(agg_outputs) <= 1
    if tails or agg_outputs:
        assert agg_outputs == [{"v": expected}]


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(
    outage_start=st.floats(min_value=0.0, max_value=400.0),
    outage_len=st.floats(min_value=10.0, max_value=2000.0),
    seed=st.integers(min_value=0, max_value=10_000),
)
def test_exactly_once_under_outage_with_failover(outage_start, outage_len, seed):
    """A whole-cloud outage mid-workflow: failover keeps the run exactly-once."""
    spec = WorkflowSpec("outage", gc=False)
    spec.function("a", AWS, workload=Workload(fixed_ms=20, fn=lambda x: x + 1))
    spec.function("b", ALI, failover=[AWS],
                  workload=Workload(fixed_ms=20, fn=lambda x: x * 2))
    spec.function("c", AWS, workload=Workload(fixed_ms=20, fn=lambda x: x - 3))
    spec.sequence("a", "b")
    spec.sequence("b", "c")
    sim = SimCloud(seed=seed)
    dep = wf.deploy(sim, spec)
    sim.schedule_outage("aliyun", outage_start, outage_start + outage_len)
    wid = dep.start(5)
    sim.run()
    cs = [r for r in dep.executions(wid) if r.function == "c"
          and r.status == "done"]
    assert cs, "workflow must complete despite the outage"
    assert all(r.result == (5 + 1) * 2 - 3 for r in cs)
    # at-most-once invocation: downstream of b, c commits one output
    c_outs = [s.state.get(k) for s in sim.stores.values()
              for k in s.state.items if "/c_" in k and k.endswith("-output")]
    assert len(c_outs) == 1
