"""Per-arch smoke tests (reduced configs): one forward/train step on CPU,
shape + finiteness assertions, and prefill↔decode consistency."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import make_batch
from repro.models import lm

L = 24
B = 2


def _batch(cfg, key):
    lt = L - cfg.n_patches
    out = {
        "tokens": jax.random.randint(key, (B, lt), 0, cfg.vocab),
        "labels": jax.random.randint(key, (B, lt), 0, cfg.vocab),
        "mask": jnp.ones((B, lt), jnp.float32),
    }
    if cfg.n_patches:
        out["patches"] = jax.random.normal(key, (B, cfg.n_patches, 1024),
                                           jnp.bfloat16)
    if cfg.frame_input:
        out["frames"] = jax.random.normal(key, (B, L // 8, 1024), jnp.bfloat16)
    return out


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_forward_and_grad(arch):
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(1)
    params = lm.init(key, cfg)
    batch = _batch(cfg, key)
    logits, aux = lm.forward(params, cfg, batch["tokens"],
                             patches=batch.get("patches"),
                             frames=batch.get("frames"))
    lt = L - cfg.n_patches
    assert logits.shape == (B, lt + cfg.n_patches, cfg.padded_vocab)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    loss, metrics = lm.loss_fn(params, cfg, batch)
    assert np.isfinite(float(loss)) and float(loss) > 0
    grads = jax.grad(lambda p: lm.loss_fn(p, cfg, batch)[0])(params)
    gn = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
             for g in jax.tree.leaves(grads))
    assert np.isfinite(gn) and gn > 0


@pytest.mark.parametrize("arch", ["mamba2-370m", "gemma2-27b", "yi-9b",
                                  "recurrentgemma-9b", "deepseek-moe-16b"])
def test_prefill_decode_matches_forward(arch):
    """decode_step at position t must reproduce forward's logits at t."""
    cfg = configs.get_smoke(arch)
    key = jax.random.PRNGKey(2)
    params = lm.init(key, cfg)
    toks = jax.random.randint(key, (B, L), 0, cfg.vocab)

    logits_all, _ = lm.forward(params, cfg, toks)
    cache, logits_pre = lm.prefill(params, cfg, toks[:, :-1], max_len=L + 4)
    # prefill's last logits == forward's logits at position L-2
    np.testing.assert_allclose(np.asarray(logits_pre, np.float32),
                               np.asarray(logits_all[:, -2, :], np.float32),
                               atol=3e-2, rtol=3e-2)
    # decoding the final token reproduces forward's last-position logits
    logits_dec, cache = lm.decode_step(params, cfg, toks[:, -1:], cache)
    np.testing.assert_allclose(np.asarray(logits_dec, np.float32),
                               np.asarray(logits_all[:, -1, :], np.float32),
                               atol=3e-2, rtol=3e-2)


def test_vocab_padding():
    cfg = configs.get("mamba2-370m")
    assert cfg.padded_vocab % 128 == 0 and cfg.padded_vocab >= cfg.vocab
    cfg2 = configs.get("seamless-m4t-medium")
    assert cfg2.padded_vocab % 128 == 0


def test_remainder_layers_used():
    """recurrentgemma smoke: 5 layers, pattern of 3 ⇒ 1 group + 2 remainder."""
    cfg = configs.get_smoke("recurrentgemma-9b")
    params = lm.init(jax.random.PRNGKey(0), cfg)
    assert "rem" in params and len(params["rem"]) == 2
    g = jax.tree.leaves(params["blocks"])[0].shape[0]
    assert g == 1


def test_moe_ep_matches_ref_structuredly():
    """Without a mesh ctx, apply == apply_ref (same path)."""
    from repro.models import moe
    cfg = configs.get_smoke("deepseek-moe-16b")
    key = jax.random.PRNGKey(3)
    p = moe.init(key, cfg)
    x = jax.random.normal(key, (2, 8, cfg.d_model), jnp.float32)
    np.testing.assert_allclose(np.asarray(moe.apply(p, cfg, x)),
                               np.asarray(moe.apply_ref(p, cfg, x)), atol=1e-6)
