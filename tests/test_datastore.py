"""Table-2 datastore primitives: atomicity contracts the paper relies on."""

import pytest

from repro.backends.datastore import InMemoryDS, TableState


def test_create_if_absent_once():
    st = TableState("t")
    assert st.create_if_absent("k", {"v": 1}) is True
    assert st.create_if_absent("k", {"v": 2}) is False
    assert st.get("k") == {"v": 1}


def test_get_returns_copy():
    st = TableState("t")
    st.create_if_absent("k", {"v": [1]})
    got = st.get("k")
    got["v"].append(2)
    assert st.get("k") == {"v": [1]}


def test_append_and_get_list():
    st = TableState("t")
    assert st.append_and_get_list("l", ["a"]) == ["a"]
    assert st.append_and_get_list("l", ["b", "c"]) == ["a", "b", "c"]


def test_append_creates_if_absent():
    """Fig-8 safety: append works even if the create was lost to a crash."""
    st = TableState("t")
    assert st.append_and_get_list("never-created", ["x"]) == ["x"]


def test_bitmap():
    ds = InMemoryDS()
    assert ds.create_bitmap(3, "bm") is True
    assert ds.create_bitmap(3, "bm") is False
    assert ds.update_bitmap(1, "bm") == [False, True, False]
    assert ds.update_bitmap(0, "bm") == [True, True, False]
    assert ds.update_bitmap(2, "bm") == [True, True, True]


def test_prefix_gc():
    st = TableState("t")
    for k in ("wf1/a-output", "wf1/b-ivk", "wf2/a-output"):
        st.create_if_absent(k, 1)
    keys = st.list_prefix("wf1/")
    assert keys == ["wf1/a-output", "wf1/b-ivk"]
    assert st.delete(keys) == 2
    assert st.list_prefix("wf1/") == []
    assert st.list_prefix("wf2/") == ["wf2/a-output"]
