"""Table-2 datastore primitives: atomicity contracts the paper relies on."""

import pytest

from repro.backends.datastore import InMemoryDS, TableState


def test_create_if_absent_once():
    st = TableState("t")
    assert st.create_if_absent("k", {"v": 1}) is True
    assert st.create_if_absent("k", {"v": 2}) is False
    assert st.get("k") == {"v": 1}


def test_get_returns_copy():
    st = TableState("t")
    st.create_if_absent("k", {"v": [1]})
    got = st.get("k")
    got["v"].append(2)
    assert st.get("k") == {"v": [1]}


def test_append_and_get_list():
    st = TableState("t")
    assert st.append_and_get_list("l", ["a"]) == ["a"]
    assert st.append_and_get_list("l", ["b", "c"]) == ["a", "b", "c"]


def test_append_creates_if_absent():
    """Fig-8 safety: append works even if the create was lost to a crash."""
    st = TableState("t")
    assert st.append_and_get_list("never-created", ["x"]) == ["x"]


def test_bitmap():
    ds = InMemoryDS()
    assert ds.create_bitmap(3, "bm") is True
    assert ds.create_bitmap(3, "bm") is False
    assert ds.update_bitmap(1, "bm") == [False, True, False]
    assert ds.update_bitmap(0, "bm") == [True, True, False]
    assert ds.update_bitmap(2, "bm") == [True, True, True]


def test_prefix_gc():
    st = TableState("t")
    for k in ("wf1/a-output", "wf1/b-ivk", "wf2/a-output"):
        st.create_if_absent(k, 1)
    keys = st.list_prefix("wf1/")
    assert keys == ["wf1/a-output", "wf1/b-ivk"]
    assert st.delete(keys) == 2
    assert st.list_prefix("wf1/") == []
    assert st.list_prefix("wf2/") == ["wf2/a-output"]


# ==========================================================================
# Cross-process durability: the WAL-backed tables under fork + flock
# ==========================================================================
#
# The remote substrate shares one WAL per table between the coordinator and
# its forked workers.  These regressions pin the cross-process contract:
# flock-serialized appends (no interleaved half-records), linearizable
# create_if_absent (one winner), and torn-tail truncation that never eats a
# record another live writer just committed.

import multiprocessing as _mp  # noqa: E402
import os as _os               # noqa: E402

from repro.backends.datastore import (  # noqa: E402
    PersistentTableState, SharedTableState)

_fork = _mp.get_context("fork")


def _spawn(fn, *args):
    p = _fork.Process(target=fn, args=args, daemon=True)
    p.start()
    return p


def _join_all(procs, timeout=60.0):
    for p in procs:
        p.join(timeout)
        assert p.exitcode == 0, f"child {p.pid} exited {p.exitcode}"


def test_shared_table_concurrent_appends_across_processes(tmp_path):
    """Two+ forked processes hammer one list key through SharedTableState:
    every append must survive — distinct, complete, no torn interleave."""
    path = str(tmp_path / "t.wal")
    writers, per = 4, 25

    def work(w):
        st = SharedTableState("t", path)
        for i in range(per):
            st.append_and_get_list("l", [f"{w}:{i}"])
        st.close()

    _join_all([_spawn(work, w) for w in range(writers)])
    st = SharedTableState("t", path)
    st.sync()
    got = st.get("l")
    assert sorted(got) == sorted(
        f"{w}:{i}" for w in range(writers) for i in range(per))
    st.close()


def test_shared_table_create_if_absent_one_winner_across_processes(tmp_path):
    """The linearizable-create contract across real processes: N racers,
    exactly one True, and every loser observes the winner's value."""
    path = str(tmp_path / "t.wal")

    def race(w):
        st = SharedTableState("t", path)
        won = st.create_if_absent("crown", {"by": w})
        # report through the same table — the thing under test is also
        # the only channel guaranteed to survive the child
        st.append_and_get_list("results", [(w, won, st.get("crown"))])
        st.close()

    _join_all([_spawn(race, w) for w in range(4)])
    st = SharedTableState("t", path)
    st.sync()
    results = st.get("results")
    winners = [w for (w, won, _) in results if won]
    assert len(winners) == 1
    assert all(seen == {"by": winners[0]} for (_, _, seen) in results)
    st.close()


def test_persistent_table_flock_serializes_two_appending_processes(tmp_path):
    """Regression for the torn-tail bug: two processes appending through
    PersistentTableState share one WAL; without the cross-process flock
    their pickle frames interleave and replay stops at the first tear.
    With it, a fresh replay must recover every record."""
    path = str(tmp_path / "p.wal")
    per = 40

    def work(w):
        st = PersistentTableState("p", path)
        # large-ish values make unserialized interleaving near-certain
        for i in range(per):
            st.append_and_get_list(f"l{w}", [{"w": w, "i": i,
                                              "pad": "x" * 512}])
        st.close()

    _join_all([_spawn(work, 0), _spawn(work, 1)])
    fresh = PersistentTableState("p", path)
    for w in (0, 1):
        got = fresh.get(f"l{w}")
        assert [e["i"] for e in got] == list(range(per))
    fresh.close()


def test_torn_tail_truncated_without_eating_committed_records(tmp_path):
    """A half-written tail record (writer died mid-append) is dropped on
    the next open — and only the tail: everything committed before it
    replays, and the truncated WAL accepts new appends cleanly."""
    path = str(tmp_path / "t.wal")
    st = SharedTableState("t", path)
    st.create_if_absent("k", {"v": 1})
    st.append_and_get_list("l", ["a", "b"])
    st.close()
    with open(path, "ab") as f:        # the torn tail
        f.write(b"\x80\x04\x95GARBAGE")
    fresh = SharedTableState("t", path)
    fresh.sync()
    assert fresh.get("k") == {"v": 1}
    assert fresh.get("l") == ["a", "b"]
    fresh.append_and_get_list("l", ["c"])
    fresh.close()
    again = SharedTableState("t", path)
    again.sync()
    assert again.get("l") == ["a", "b", "c"]
    again.close()
