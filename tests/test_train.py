"""Optimizer math, microbatch equivalence, loss-goes-down, checkpoints,
and the Jointλ step-commit protocol (exactly-once across failover)."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.data.synthetic import SyntheticLM, make_batch
from repro.models import lm
from repro.train import checkpoint as ckpt
from repro.train import optim
from repro.train.commit import CommittedTrainer
from repro.train.step import make_train_step, train_state_init


def test_adamw_matches_reference():
    """One AdamW step vs a hand-rolled numpy reference."""
    p = {"w": jnp.array([1.0, -2.0]), "b": jnp.array([[0.5, 0.5], [1.0, 1.0]])}
    g = {"w": jnp.array([0.1, 0.2]), "b": jnp.array([[1.0, -1.0], [0.0, 2.0]])}
    opt = optim.adamw_init(p)
    newp, newopt = optim.adamw_update(p, g, opt, jnp.int32(0), lr=0.1,
                                      b1=0.9, b2=0.95, weight_decay=0.0)
    for k in p:
        m = 0.1 * np.asarray(g[k])
        v = 0.05 * np.asarray(g[k]) ** 2
        mhat = m / (1 - 0.9)
        vhat = v / (1 - 0.95)
        ref = np.asarray(p[k]) - 0.1 * mhat / (np.sqrt(vhat) + 1e-8)
        np.testing.assert_allclose(np.asarray(newp[k]), ref, atol=1e-6)


def test_weight_decay_skips_vectors():
    p = {"w2d": jnp.ones((2, 2)), "w1d": jnp.ones((2,))}
    g = {"w2d": jnp.zeros((2, 2)), "w1d": jnp.zeros((2,))}
    newp, _ = optim.adamw_update(p, g, optim.adamw_init(p), jnp.int32(0),
                                 lr=0.1, weight_decay=0.5)
    assert float(newp["w2d"][0, 0]) < 1.0      # decayed
    assert float(newp["w1d"][0]) == 1.0        # not decayed


def test_clip_by_global_norm():
    g = {"a": jnp.full((3,), 4.0)}
    clipped, norm = optim.clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(np.sqrt(48.0))
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_cosine_schedule():
    lr0 = optim.cosine_lr(jnp.int32(0), base_lr=1.0, warmup=10, total=100)
    lr_w = optim.cosine_lr(jnp.int32(10), base_lr=1.0, warmup=10, total=100)
    lr_end = optim.cosine_lr(jnp.int32(100), base_lr=1.0, warmup=10, total=100)
    assert float(lr0) == 0.0
    assert float(lr_w) == pytest.approx(1.0)
    assert float(lr_end) == pytest.approx(0.1, rel=1e-3)


def test_microbatch_equivalence():
    """n microbatches of b/n ≡ one batch of b (same grads, fp32 accum)."""
    cfg = configs.get_smoke("yi-9b").replace(remat="none",
                                             compute_dtype="float32")
    key = jax.random.PRNGKey(0)
    state = train_state_init(key, cfg)
    batch = {k: jnp.asarray(v) for k, v in
             make_batch(cfg, 16, 4, step=0).items()}
    s1, m1 = jax.jit(make_train_step(cfg, lr=1e-3))(state, batch)
    s2, m2 = jax.jit(make_train_step(cfg, lr=1e-3, microbatches=2))(state, batch)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    for a, b in zip(jax.tree.leaves(s1["params"]), jax.tree.leaves(s2["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=2e-5)


def test_loss_decreases():
    cfg = configs.get_smoke("yi-9b")
    key = jax.random.PRNGKey(0)
    state = train_state_init(key, cfg)
    step = jax.jit(make_train_step(cfg, lr=3e-3))
    ds = SyntheticLM(cfg.vocab, 32, 8, seed=0)
    losses = []
    for s in range(25):
        batch = {k: jnp.asarray(v) for k, v in ds.batch(s).items()}
        state, m = step(state, batch)
        losses.append(float(m["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.1, losses


def test_checkpoint_roundtrip(tmp_path):
    cfg = configs.get_smoke("mamba2-370m")
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    ckpt.save(state, str(tmp_path), 7)
    template = jax.eval_shape(lambda: train_state_init(jax.random.PRNGKey(0), cfg))
    restored = ckpt.restore(template, str(tmp_path))
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_keep(tmp_path):
    cfg = configs.get_smoke("mamba2-370m")
    state = train_state_init(jax.random.PRNGKey(0), cfg)
    for s in range(5):
        ckpt.save(state, str(tmp_path), s, keep=2)
    assert ckpt.all_steps(str(tmp_path)) == [3, 4]


def test_committed_trainer_failover_exactly_once(tmp_path):
    """The headline integration: identical training trajectory with and
    without a mid-run controller failure (Jointλ §4.1+§4.2 on real JAX)."""
    cfg = configs.get_smoke("yi-9b").replace(remat="none")
    t1 = CommittedTrainer(cfg, seq_len=16, global_batch=2,
                          ckpt_dir=str(tmp_path / "a"), steps_per_chunk=4)
    r1 = t1.train(12)
    t2 = CommittedTrainer(cfg, seq_len=16, global_batch=2,
                          ckpt_dir=str(tmp_path / "b"), steps_per_chunk=4)
    r2 = t2.train(12, fail_primary_at_chunk=2)
    assert r1.step == r2.step == 12
    assert r1.loss == pytest.approx(r2.loss, abs=1e-4)


def test_data_determinism():
    ds = SyntheticLM(1000, 16, 8, seed=3)
    b1, b2 = ds.batch(5), ds.batch(5)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # host sharding partitions the batch deterministically
    h0 = ds.batch(5, host_index=0, host_count=2)
    assert h0["tokens"].shape[0] == 4


def test_data_has_structure():
    """The Markov backoff must make next-token prediction learnable."""
    ds = SyntheticLM(500, 256, 4, seed=0)
    b = ds.batch(0)
    pairs = {}
    for row_t, row_l in zip(b["tokens"], b["labels"]):
        for t, l in zip(row_t, row_l):
            pairs.setdefault(int(t), []).append(int(l))
    # for tokens seen ≥8 times, successors concentrate (not uniform)
    concentrated = 0
    checked = 0
    for t, succs in pairs.items():
        if len(succs) >= 8:
            checked += 1
            top = max(np.bincount(succs))
            if top / len(succs) > 0.2:
                concentrated += 1
    assert checked > 0 and concentrated / checked > 0.5
