"""Durable execution: event-sourced effect journal, replay recovery, and
Sleep/WaitForSignal suspension — on both substrates.

The contract under test (docs/backends.md §4, ``repro/core/durable.py``):

- ``deploy(..., durable=True)`` commits every effect result to the node's
  home table *before* the generator resumes; a fresh backend over the same
  stores replays the journal to the exact suspension point with live side
  effects suppressed — exactly-once across the crash boundary.
- ``Sleep(ms)`` / ``WaitForSignal(name)`` park an attempt without holding a
  concurrency slot: O(1) events for an hour-long virtual-time sleep on
  SimCloud, a freed worker thread on LocalRunner.
- The ``journal``/``signal`` capabilities are optional and probed; GC never
  reclaims keys of a started-but-unfinished journal.

The kill -9 variant of the recovery path is the CI gate
``benchmarks/durability_smoke.py``; randomized crash schedules live in
``tests/test_exactly_once_prop.py``.
"""

import os
import time
from collections import Counter

import pytest

from repro.backends import shim
from repro.backends.datastore import TableState
from repro.backends.localjax import LocalRunner
from repro.backends.simcloud import SimCloud, Workload
from repro.core import workflow as wf
from repro.core.orchestrator import gc_handler
from repro.core.subgraph import WorkflowSpec

from conftest import (ALI, AWS, FileCalls, close_backend, make_backend,
                      two_stage_spec)


# ==========================================================================
# SimCloud: replay recovery, zero-slot suspension, signals
# ==========================================================================


def test_simcloud_outage_then_fresh_backend_resume_exactly_once():
    """Kill a durable workflow via a permanent outage, rehydrate it on a
    *fresh* SimCloud over the same stores: replay reaches the identical
    final result and the user function still ran exactly once."""
    calls = []
    sim = SimCloud(seed=4)
    dep = wf.deploy(sim, two_stage_spec(calls), durable=True)
    sim.schedule_outage("aliyun", 5.0, float("inf"))
    wid = dep.start(3)
    sim.run()
    assert dep.result_of(wid, "b") is None       # b never survived the outage
    assert sim.dropped

    fresh = SimCloud(seed=99)                    # different seed on purpose
    fresh.adopt_stores(sim)
    dep2 = wf.deploy(fresh, two_stage_spec(calls), durable=True)
    fids = dep2.resume()
    assert fids, "an open journal must be rehydrated"
    fresh.run()
    assert dep2.result_of(wid, "b") == 16
    assert calls == [6], "exactly one live user execution across both lives"

    # the journal round-trip is closed: nothing left to resume
    third = SimCloud(seed=1)
    third.adopt_stores(fresh)
    dep3 = wf.deploy(third, two_stage_spec(calls), durable=True)
    assert dep3.resume() == []


def test_simcloud_hour_long_sleep_is_o1_events_and_zero_slots():
    """A 1-hour virtual sleep costs O(1) heap events and holds no slot:
    with concurrency=1, a second workflow runs to completion *during* the
    suspension."""
    calls = []
    sim = SimCloud(seed=0, concurrency={"aliyun/fc": 1})
    sleeper = wf.deploy(sim, two_stage_spec(calls, sleep_ms=3_600_000.0),
                        durable=True)
    quick_spec = WorkflowSpec("quick", gc=False)
    quick_spec.function("q", ALI, workload=Workload(fn=lambda e: e + 1))
    quick = wf.deploy(sim, quick_spec)

    ws = sleeper.start(1)
    wq = quick.start(0, t=1000.0)                # arrives mid-suspension
    sim.run()

    assert sleeper.result_of(ws, "b") == 12
    assert quick.result_of(wq, "q") == 1
    q_done = [r for r in quick.executions(wq) if r.status == "done"]
    assert max(r.t_end for r in q_done) < 3_600_000.0, \
        "the quick workflow must not wait behind the sleeping one's slot"
    assert sim.now >= 3_600_000.0
    assert sim.events_processed < 200, \
        f"hour-long sleep must be O(1) events, saw {sim.events_processed}"


def test_simcloud_wait_signal_gates_completion_and_latch_is_first_wins():
    calls = []
    sim = SimCloud(seed=0)
    dep = wf.deploy(sim, two_stage_spec(calls, wait_signal="go"), durable=True)
    wid = dep.start(30)
    sim.run()
    assert dep.result_of(wid, "b") is None       # suspended, not failed
    assert any(r.status == "suspended" for r in dep.executions(wid))
    assert not sim.dropped

    dep.signal(wid, "go")
    dep.signal(wid, "go", value="late loser")    # first delivery wins
    sim.run()
    assert dep.result_of(wid, "b") == 70
    assert calls == [60]


def test_signal_delay_contract_honored_in_virtual_time():
    calls = []
    sim = SimCloud(seed=0)
    dep = wf.deploy(sim, two_stage_spec(calls, wait_signal="go"), durable=True)
    wid = dep.start(1)
    dep.signal(wid, "go", t=5_000.0)
    sim.run()
    assert dep.result_of(wid, "b") == 12
    b_done = [r for r in dep.executions(wid)
              if r.function == "b" and r.status == "done"]
    assert min(r.t_end for r in b_done) >= 5_000.0


# ==========================================================================
# LocalRunner: suspension on real threads, WAL recovery
# ==========================================================================


def test_local_sleep_releases_the_worker_thread():
    """concurrency=1: a second workflow on the same FaaS completes while the
    first is parked mid-sleep — suspension holds no worker."""
    spec = WorkflowSpec("lslp", gc=False)
    spec.function("s", AWS, sleep_ms=600.0,
                  workload=Workload(fn=lambda e: e + 1))
    quick_spec = WorkflowSpec("lq", gc=False)
    quick_spec.function("q", AWS, workload=Workload(fn=lambda e: e * 3))

    runner = LocalRunner(concurrency=1)
    sleeper = wf.deploy(runner, spec, durable=True)
    quick = wf.deploy(runner, quick_spec)
    t0 = time.monotonic()
    ws = sleeper.start(1)
    wq = quick.start(2, t=100.0)
    runner.run(timeout_s=30.0)
    elapsed_ms = (time.monotonic() - t0) * 1e3

    assert sleeper.result_of(ws, "s") == 2
    assert quick.result_of(wq, "q") == 6
    q_rec = [r for r in quick.executions(wq) if r.status == "done"][0]
    assert q_rec.t_end - q_rec.t_queued < 450.0, \
        "quick workflow must not queue behind the 600 ms suspension"
    assert elapsed_ms >= 550.0                       # the sleep was honored


def test_local_wal_crash_resume_exactly_once(tmp_path):
    """Crash every attempt of b (retry budget exhausted, journal left open),
    then resume a fresh runner over the same WAL directory: identical final
    result, user function executed exactly once overall."""
    calls = []
    store_dir = str(tmp_path / "wal")

    r1 = LocalRunner(concurrency=2, max_requeues=1, retry_backoff_ms=5.0,
                     store_dir=store_dir)
    dep1 = wf.deploy(r1, two_stage_spec(calls), durable=True)
    r1.crash_policy = (lambda ex, eff:
                       ex.record.function == "b" and ex.effect_index >= 4)
    wid = dep1.start(3, workflow_id="dur-000000")
    r1.run(timeout_s=30.0)
    assert r1.drop_count >= 1
    assert dep1.result_of(wid, "b") is None
    r1.close()

    r2 = LocalRunner(concurrency=2, store_dir=store_dir)
    dep2 = wf.deploy(r2, two_stage_spec(calls), durable=True)
    fids = dep2.resume()
    assert fids
    r2.run(timeout_s=30.0)
    r2.close()
    assert dep2.result_of(wid, "b") == 16
    assert calls == [6]


def test_local_signal_latch_survives_process_boundary(tmp_path):
    """Signal delivered, then the runner 'dies' before the waiter wakes:
    the WAL-persisted latch lets the resumed attempt observe it."""
    calls = []
    store_dir = str(tmp_path / "wal")
    spec = lambda: two_stage_spec(calls, wait_signal="go")  # noqa: E731

    r1 = LocalRunner(concurrency=2, max_requeues=0, retry_backoff_ms=5.0,
                     store_dir=store_dir)
    dep1 = wf.deploy(r1, spec(), durable=True)
    # crash b after the journal opens but before it reaches the wait:
    # journal open, user code never ran
    r1.crash_policy = (lambda ex, eff:
                       ex.record.function == "b" and ex.effect_index >= 2)
    wid = dep1.start(5, workflow_id="dur-000000")
    r1.run(timeout_s=30.0)
    dep1.signal(wid, "go")                     # latch lands in the WAL
    r1.close()

    r2 = LocalRunner(concurrency=2, store_dir=store_dir)
    dep2 = wf.deploy(r2, spec(), durable=True)
    assert dep2.resume()
    r2.run(timeout_s=30.0)
    r2.close()
    assert dep2.result_of(wid, "b") == 20
    assert calls == [10]


# ==========================================================================
# RemoteRunner: suspension across worker *processes*, durable latches
# ==========================================================================


def test_remote_sleep_parks_without_worker_or_lease(tmp_path):
    """A remote Sleep holds no worker process and no lease: the parked
    state is an acked message plus a not-yet-due wake message in the shared
    broker, so the residual sleep is honored in wall-clock time and the
    user function still runs exactly once."""
    calls = FileCalls(os.path.join(str(tmp_path), "calls.log"))
    backend = make_backend("remote")
    try:
        dep = wf.deploy(backend, two_stage_spec(calls, sleep_ms=350.0),
                        durable=True)
        wid = dep.start(3, workflow_id="rslp-000000")
        elapsed_ms = backend.run(timeout_s=60.0)
        assert dep.result_of(wid, "b") == 16
        assert calls.values() == [6]
        assert elapsed_ms >= 300.0, "the sleep must be honored, not skipped"
        assert any(r.status == "suspended"
                   for r in backend.executions_of("b"))
    finally:
        close_backend(backend)


def test_remote_wait_signal_parks_and_latch_is_first_wins(tmp_path):
    """A remote signal wait parks with *no* pending delivery: run() returns
    with the workflow suspended (exactly like SimCloud), the durable latch
    makes the parked message claimable, and the first delivery wins."""
    calls = FileCalls(os.path.join(str(tmp_path), "calls.log"))
    backend = make_backend("remote")
    try:
        dep = wf.deploy(backend, two_stage_spec(calls, wait_signal="go"),
                        durable=True)
        wid = dep.start(30, workflow_id="rsig-000000")
        backend.run(timeout_s=60.0)
        assert dep.result_of(wid, "b") is None    # suspended, not failed
        assert any(r.status == "suspended"
                   for r in backend.executions_of("b"))
        assert not backend.dropped

        dep.signal(wid, "go")
        dep.signal(wid, "go", value="late loser")  # first delivery wins
        backend.run(timeout_s=60.0)
        assert dep.result_of(wid, "b") == 70
        assert calls.values() == [60]
    finally:
        close_backend(backend)


def test_remote_signal_latch_survives_the_whole_pool(tmp_path):
    """Signal delivered while no pool is alive, then a *fresh* runner over
    the same store: the WAL-persisted latch lets the parked waiter complete
    — the remote analogue of the LocalRunner process-boundary test."""
    calls = FileCalls(os.path.join(str(tmp_path), "calls.log"))
    old = make_backend("remote")
    try:
        dep1 = wf.deploy(old, two_stage_spec(calls, wait_signal="go"),
                         durable=True)
        wid = dep1.start(5, workflow_id="rlat-000000")
        old.run(timeout_s=60.0)                    # returns parked
        dep1.signal(wid, "go")                     # latch lands in the WAL

        fresh = make_backend("remote", store_dir=old.store_dir)
        try:
            dep2 = wf.deploy(fresh, two_stage_spec(calls, wait_signal="go"),
                             durable=True)
            fresh.run(timeout_s=60.0)
            assert dep2.result_of(wid, "b") == 20
            assert calls.values() == [10]
        finally:
            close_backend(fresh)
    finally:
        close_backend(old)


# ==========================================================================
# Capability probes, Parallel guard, GC awareness
# ==========================================================================


def test_resume_without_journal_capability_is_a_clear_error():
    """An in-memory LocalRunner cannot replay (its journal dies with the
    process): resume() must raise CapabilityError naming the capability."""
    calls = []
    runner = LocalRunner()
    dep = wf.deploy(runner, two_stage_spec(calls), durable=True)
    with pytest.raises(shim.CapabilityError, match="journal"):
        dep.resume()


def test_signal_without_capability_is_a_clear_error():
    calls = []
    sim = SimCloud(seed=0)
    dep = wf.deploy(sim, two_stage_spec(calls), durable=True)
    dep.backend = object()                      # a backend with no signal()
    with pytest.raises(shim.CapabilityError, match="signal"):
        dep.signal("w", "go")


@pytest.mark.parametrize("kind", ["sim", "local", "remote"])
def test_suspension_inside_parallel_is_rejected(kind):
    """Suspension is attempt-granular: Sleep/WaitForSignal inside Parallel
    must fail loudly on every backend, not strand sibling branches."""
    backend = make_backend(kind, **({} if kind == "sim"
                                    else {"max_requeues": 0}))

    def handler(event):
        yield shim.Parallel([shim.Sleep(5.0), shim.Now()])

    try:
        backend.deploy(shim.Deployment(function="bad", faas=AWS,
                                       handler=handler,
                                       workload=shim.Workload()))
        backend.submit(AWS, "bad", {"workflow_id": "p", "input": 0})
        if kind == "sim":
            backend.run()
        else:
            backend.run(timeout_s=30.0)
        assert not any(r.status == "done"
                       for r in backend.executions_of("bad"))
    finally:
        close_backend(backend)


def _drive_gc(state: TableState, prefix: str):
    """Interpret gc_handler's effect stream against one raw table state."""
    gen = gc_handler({"prefix": prefix, "stores": [state.name]})
    value = None
    while True:
        try:
            eff = gen.send(value)
        except StopIteration:
            return
        if type(eff) is shim.DsListPrefix:
            value = state.list_prefix(eff.prefix)
        elif type(eff) is shim.DsDelete:
            value = state.delete(eff.keys)
        else:
            value = None


def test_gc_spares_open_journals_and_signal_latches():
    """GC must not reclaim a suspended workflow: keys of any function id
    with a start-but-no-done journal — and the workflow's signal latches —
    survive the sweep; a later sweep reclaims them once the journal closes."""
    st = TableState("aws/dynamodb")
    # b_0 is suspended (open journal); a_0 completed (closed journal)
    for k in ["w1/a_0-output", "w1/a_0#j/start", "w1/a_0#j/e000001",
              "w1/a_0#j/done",
              "w1/b_0-output", "w1/b_0#j/start", "w1/b_0#j/e000001",
              "w1/__signal__/go"]:
        st.create_if_absent(k, {"v": 1})
    _drive_gc(st, "w1/")
    remaining = set(st.items)
    assert remaining == {"w1/b_0-output", "w1/b_0#j/start",
                         "w1/b_0#j/e000001", "w1/__signal__/go"}, remaining

    # the journal closes → the next best-effort sweep reclaims everything
    st.create_if_absent("w1/b_0#j/done", {"r": None})
    _drive_gc(st, "w1/")
    assert not st.items


def test_durable_end_to_end_gc_reclaims_all_but_the_open_terminal():
    """End-to-end: a durable workflow with GC enabled completes and the
    sweep reclaims every upstream checkpoint/journal key.  The terminal
    attempt's own journal is necessarily still open when it runs the sweep
    (its done marker lands after), so only terminal-fid keys may survive —
    that is exactly the journal-awareness that keeps suspended workflows
    recoverable."""
    calls = []
    spec = two_stage_spec(calls)
    spec.gc_enabled = True
    sim = SimCloud(seed=0)
    dep = wf.deploy(sim, spec, durable=True)
    wid = dep.start(3)
    sim.run()
    assert dep.result_of(wid, "b") == 16
    leftovers = [k for s in sim.stores.values() for k in s.state.items
                 if k.startswith(wid + "/")]
    assert leftovers, "the open terminal journal must have been spared"
    stray = [k for k in leftovers if not k.startswith(f"{wid}/b_")]
    assert not stray, stray


# ==========================================================================
# Replay determinism: completed journals are inert
# ==========================================================================


@pytest.mark.parametrize("kind", ["sim", "local"])
def test_completed_journal_replays_to_identical_results(kind):
    """Re-delivering a *completed* durable attempt (at-least-once is allowed
    to do that at any time) replays entirely from the journal: same result,
    no new live user execution."""
    calls = []
    if kind == "sim":
        backend = SimCloud(seed=0)
    else:
        backend = LocalRunner(concurrency=2)
    dep = wf.deploy(backend, two_stage_spec(calls), durable=True)
    wid = dep.start(3)
    run_kw = {} if kind == "sim" else {"timeout_s": 30.0}
    backend.run(**run_kw)
    assert dep.result_of(wid, "b") == 16
    assert calls == [6]

    # duplicate delivery of the whole entry function: pure replay
    backend.submit(AWS, "a", {"workflow_id": wid, "input": 3})
    backend.run(**run_kw)
    done = Counter(r.function for r in dep.executions(wid)
                   if r.status == "done")
    assert calls == [6], "replay must suppress the live user execution"
    assert done["a"] >= 2 and done["b"] >= 1
    results = {r.result for r in dep.executions(wid)
               if r.function == "b" and r.status == "done"}
    assert results == {16}
